"""Tier-generic topology core: K-level hierarchies, K-vector rates, the
tier seam, the K-tier fluid capacity vs a brute-force LP, per-rack arrival
weights, and the bitwise pre-refactor pins.

The pinned values were recorded from the 3-tier code before the
tier-generic refactor (same container, jax 0.4.37); the K=3 flat-rack
default must keep reproducing those sample paths exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import workloads as wl
from repro.core import locality as loc, simulator as sim
from repro.core.cluster import pair_worker_tiers, tier_of, worker_tiers
from repro.core.policy import PolicyConfig


# ----------------------------------------------------------- construction --

def test_topology_levels_and_tiers():
    flat = loc.Topology(16)                      # no grouping: K = 2
    assert flat.depth == 0 and flat.num_tiers == 2
    assert flat.num_racks == 1 and flat.min_rack_size == 16
    assert flat.ancestors.shape == (0, 16)

    rack = loc.Topology(24, 6)                   # the paper's default: K = 3
    assert rack.depth == 1 and rack.num_tiers == 3
    assert rack.num_racks == 4 and rack.servers_per_rack == 6
    np.testing.assert_array_equal(rack.rack_of, np.arange(24) // 6)
    assert rack == loc.Topology(24, (6,))        # legacy int == 1-level spec

    pods = loc.Topology(24, (4, 12))             # racks in pods: K = 4
    assert pods.depth == 2 and pods.num_tiers == 4
    assert pods.num_racks == 6
    np.testing.assert_array_equal(pods.ancestors[0], np.arange(24) // 4)
    np.testing.assert_array_equal(pods.ancestors[1], np.arange(24) // 12)


def test_topology_heterogeneous_groups():
    topo = loc.Topology(24, ((6, 6, 4, 4, 4),))
    assert topo.num_racks == 5 and topo.min_rack_size == 4
    np.testing.assert_array_equal(
        topo.rack_of, np.repeat([0, 1, 2, 3, 4], [6, 6, 4, 4, 4]))
    with pytest.raises(ValueError):
        topo.servers_per_rack  # no single uniform size
    # heterogeneous pods over heterogeneous racks, nesting on boundaries
    deep = loc.Topology(24, ((4, 4, 4, 6, 6), (12, 12)))
    assert deep.num_tiers == 4
    np.testing.assert_array_equal(deep.ancestors[1], np.arange(24) // 12)


def test_topology_validation_tiling_and_nesting():
    with pytest.raises(ValueError):
        loc.Topology(10, 4)                      # does not tile (old
    with pytest.raises(ValueError):              # ClusterSpec gap)
        loc.Topology(24, ((6, 6, 6),))           # sums to 18, not 24
    with pytest.raises(ValueError):
        loc.Topology(24, (4, 10))                # pods don't tile
    with pytest.raises(ValueError):
        loc.Topology(24, ((4, 8, 12), (8, 16)))  # pod cuts a rack in half
    with pytest.raises(ValueError):
        loc.Topology(24, (12, 12))               # level must coarsen
    # legacy host-side aliases survive the retirement of ClusterSpec
    topo = loc.Topology(8, 4)
    assert topo.num_workers == 8
    np.testing.assert_array_equal(topo.pod_of, topo.rack_of)


def test_rates_k_vector():
    r3 = loc.Rates()
    assert r3.values == (0.5, 0.45, 0.25) and r3.num_tiers == 3
    assert (r3.alpha, r3.beta, r3.gamma) == (0.5, 0.45, 0.25)
    r4 = loc.Rates((0.5, 0.45, 0.35, 0.25))
    assert r4.num_tiers == 4 and r4.gamma == 0.25
    assert np.asarray(r4.as_array()).shape == (4,)
    scaled = r4.scaled(0.5)
    assert scaled.values == pytest.approx((0.25, 0.225, 0.175, 0.125))
    with pytest.raises(ValueError):
        loc.Rates((0.5, 0.45, 0.45, 0.25))       # not strictly decreasing
    with pytest.raises(ValueError):
        loc.Rates((0.5,))                        # need >= 2 tiers
    with pytest.raises(ValueError):
        sim.SimConfig(topo=loc.Topology(24, (4, 12)),
                      true_rates=loc.Rates())    # 3 rates on a 4-tier topo


# -------------------------------------------------------------- tier seam --

def brute_tier(topo, task, server):
    if server in task:
        return 0
    anc = topo.ancestors
    for lvl in range(topo.depth):
        if anc[lvl, server] in {int(anc[lvl, s]) for s in task}:
            return lvl + 1
    return topo.num_tiers - 1


@pytest.mark.parametrize("spec", [(), (6,), (4, 12), ((6, 6, 4, 4, 4),)])
def test_server_tiers_matches_bruteforce(spec):
    topo = loc.Topology(24, spec)
    anc = jnp.asarray(topo.ancestors, jnp.int32)
    rng = np.random.default_rng(0)
    for _ in range(8):
        task = sorted(rng.choice(24, 3, replace=False).tolist())
        tiers = np.asarray(loc.server_tiers(jnp.asarray(task, jnp.int32),
                                            anc))
        want = [brute_tier(topo, task, s) for s in range(24)]
        np.testing.assert_array_equal(tiers, want)
        # one-hot masks cover every server exactly once
        masks = np.asarray(loc.tier_masks(jnp.asarray(task, jnp.int32), anc))
        assert masks.shape == (topo.num_tiers, 24)
        np.testing.assert_array_equal(masks.sum(axis=0), 1)
        # host-side helpers agree with the JAX seam
        np.testing.assert_array_equal(worker_tiers(topo, task), want)
        assert all(tier_of(topo, task, s) == want[s] for s in range(24))


def test_pair_tiers_matches_hierarchy():
    topo = loc.Topology(24, (4, 12))
    anc = jnp.asarray(topo.ancestors, jnp.int32)
    sid = jnp.arange(24)
    t = np.asarray(loc.pair_tiers(jnp.int32(0), sid, anc))
    assert t[0] == 0                       # self
    assert (t[1:4] == 1).all()             # same rack of 4
    assert (t[4:12] == 2).all()            # same pod of 12
    assert (t[12:] == 3).all()             # other pod
    np.testing.assert_array_equal(pair_worker_tiers(topo, 0), t)
    # pair rates select the matching tier's rate
    rates = jnp.asarray([0.5, 0.45, 0.35, 0.25])
    np.testing.assert_allclose(
        np.asarray(loc.pair_rate(jnp.int32(0), sid, anc, rates)),
        np.asarray(rates)[t])


# ---------------------------------------------- K-tier fluid capacity LP ---

def _fluid_lp_capacity_k(topo, rates, p_hot):
    """Brute-force fluid LP for the hot-rack pattern, K-generic and
    independent of the water-filling closed form: hot traffic may be served
    by the hot rack (rate r0) or by any tier-l pool (rate r_l); uniform
    traffic is served locally (r0) anywhere."""
    import scipy.optimize as sopt
    r = np.asarray(rates.values, float)
    tier = loc.hot_rack_tiers(topo, 0)
    pools = [(r[0], int((tier <= 1).sum()))]
    pools += [(r[lvl], int((tier == lvl).sum()))
              for lvl in range(2, r.size) if (tier == lvl).sum()]
    p = len(pools)
    nvar = 1 + 2 * p  # [Lam, hot per pool, uniform per pool]
    c = np.zeros(nvar)
    c[0] = -1.0
    a_eq = np.zeros((2, nvar))
    a_eq[0, 0], a_eq[0, 1:1 + p] = -p_hot, 1.0
    a_eq[1, 0], a_eq[1, 1 + p:] = -(1.0 - p_hot), 1.0
    a_ub = np.zeros((p, nvar))
    b_ub = []
    for j, (rj, nj) in enumerate(pools):
        a_ub[j, 1 + j] = 1.0 / rj
        a_ub[j, 1 + p + j] = 1.0 / r[0]
        b_ub.append(float(nj))
    res = sopt.linprog(c, A_ub=a_ub, b_ub=b_ub, A_eq=a_eq, b_eq=[0.0, 0.0],
                       bounds=[(0, None)] * nvar)
    assert res.success, res.message
    return -res.fun


@pytest.mark.parametrize("spec,rates,p_hot", [
    ((), (0.5, 0.25), 0.5),                              # K=2
    ((), (0.5, 0.25), 1.0),
    ((4,), (0.5, 0.45, 0.25), 0.5),                      # K=3 uniform
    ((6,), (0.5, 0.45, 0.25), 0.2),
    ((6,), (0.5, 0.45, 0.25), 0.9),
    (((6, 6, 4, 4, 4),), (0.5, 0.45, 0.25), 0.8),        # K=3 heterogeneous
    ((4, 12), (0.5, 0.45, 0.35, 0.25), 0.5),             # K=4 pods
    ((4, 12), (0.5, 0.45, 0.35, 0.25), 0.95),
    (((4, 4, 4, 6, 6), (12, 12)), (0.5, 0.45, 0.35, 0.25), 0.6),  # K=4 het.
])
def test_capacity_matches_bruteforce_lp_k_tier(spec, rates, p_hot):
    pytest.importorskip("scipy")
    topo = loc.Topology(24, spec)
    r = loc.Rates(rates)
    closed = loc.capacity_hot_rack(topo, r, p_hot)
    lp = _fluid_lp_capacity_k(topo, r, p_hot)
    assert closed == pytest.approx(lp, rel=1e-6)
    # sanity: bounded by the all-local optimum, monotone in p_hot
    assert closed <= topo.num_servers * r.values[0] + 1e-9
    hotter = loc.capacity_hot_rack(topo, r, min(p_hot + 0.05, 1.0))
    assert hotter <= closed + 1e-9


def test_capacity_k3_matches_seed_closed_form():
    """The K-generic water-filling reproduces the seed's 3-tier formula."""
    topo, rates = loc.Topology(24, 6), loc.Rates(0.5, 0.45, 0.25)
    m, mr, a, g = 24, 6, 0.5, 0.25
    for p in (0.1, 0.3, 0.5, 0.8, 1.0):
        want = m * a if p * m * a <= mr * a else \
            (m - mr + mr * a / g) / ((1.0 - p) / a + p / g)
        assert loc.capacity_hot_rack(topo, rates, p) == pytest.approx(want)


# ------------------------------------------------------- bitwise K=3 pins --

# Recorded from the pre-refactor 3-tier implementation: Topology(12, 4),
# Rates(0.5, 0.45, 0.25), p_hot=0.5, max_arrivals=16, horizon=2000,
# warmup=500, lam = 0.8 * capacity, seed 3.
PINNED_12x4 = {
    "balanced_pandas": {"final_n": 27.0, "mean_delay": 4.029056549072266,
                        "mean_n": 17.190641403198242,
                        "throughput": 4.24066686630249},
    "jsq_maxweight": {"final_n": 23.0, "mean_delay": 3.957812547683716,
                      "mean_n": 16.886667251586914,
                      "throughput": 4.241333484649658},
    "priority": {"final_n": 15.0, "mean_delay": 3.951564311981201,
                 "mean_n": 16.860008239746094,
                 "throughput": 4.247333526611328},
    "fifo": {"drops": 0.0, "final_n": 292.0,
             "mean_delay": 54.13591766357422, "mean_n": 230.9799346923828,
             "throughput": 4.11133337020874},
    "pandas_po2": {"final_n": 26.0, "mean_delay": 4.019688606262207,
                   "mean_n": 17.150672912597656,
                   "throughput": 4.243333339691162},
    "blind_pandas": {"est_alpha_mean": 0.47840529680252075, "final_n": 27.0,
                     "mean_delay": 4.039682388305664,
                     "mean_n": 17.235979080200195,
                     "throughput": 4.239999771118164},
}

# Paper-scale second pin: Topology(24, 6), max_arrivals=24, horizon=1500,
# warmup=300, lam = 0.9 * capacity (= 9.0), seed 7.
PINNED_24x6 = {
    "balanced_pandas": {"final_n": 35.0, "mean_delay": 4.965092182159424,
                        "mean_n": 44.685829162597656,
                        "throughput": 9.112500190734863},
    "jsq_maxweight": {"final_n": 21.0, "mean_delay": 5.3194451332092285,
                      "mean_n": 47.87500762939453,
                      "throughput": 9.129166603088379},
}


@pytest.mark.parametrize("algo", sorted(PINNED_12x4))
def test_k3_default_reproduces_prerefactor_sample_paths(algo):
    cfg = sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                        p_hot=0.5, max_arrivals=16, horizon=2000, warmup=500)
    cap = loc.capacity_hot_rack(cfg.topo, cfg.true_rates, cfg.p_hot)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    out = sim.simulate(algo, cfg, 0.8 * cap, est, seed=3)
    for k, v in PINNED_12x4[algo].items():
        assert out[k] == pytest.approx(v, rel=1e-6, abs=1e-9), (algo, k)


@pytest.mark.parametrize("algo", sorted(PINNED_24x6))
def test_k3_paper_scale_pin(algo):
    cfg = sim.SimConfig(topo=loc.Topology(24, 6), true_rates=loc.Rates(),
                        p_hot=0.5, max_arrivals=24, horizon=1500, warmup=300)
    cap = loc.capacity_hot_rack(cfg.topo, cfg.true_rates, cfg.p_hot)
    assert cap == pytest.approx(10.0)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    out = sim.simulate(algo, cfg, 0.9 * cap, est, seed=7)
    for k, v in PINNED_24x6[algo].items():
        assert out[k] == pytest.approx(v, rel=1e-6, abs=1e-9), (algo, k)


# ------------------------------------------------------- mean_delay guard --

def test_mean_delay_guard_on_zero_and_negative_load():
    cfg = sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                        max_arrivals=8, horizon=200, warmup=50)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    out = sim.simulate("balanced_pandas", cfg, 0.0, est, seed=0)
    assert np.isnan(out["mean_delay"])       # used to divide to inf
    assert out["mean_n"] == 0.0
    with pytest.raises(ValueError):
        sim.simulate("balanced_pandas", cfg, -1.0, est, seed=0)
    with pytest.raises(ValueError):
        sim.sweep("balanced_pandas", cfg, np.array([-0.5], np.float32),
                  est[None], np.arange(1))


# ------------------------------------------------ K=4 simulator + kernels --

TOPO4 = loc.Topology(24, (4, 12))
RATES4 = loc.Rates((0.5, 0.45, 0.35, 0.25))
CFG4 = sim.SimConfig(topo=TOPO4, true_rates=RATES4, p_hot=0.5,
                     max_arrivals=16, horizon=800, warmup=200)
CAP4 = loc.capacity_hot_rack(TOPO4, RATES4, 0.5)


@pytest.mark.parametrize("policy", [
    "balanced_pandas", "jsq_maxweight", "priority", "fifo", "pandas_po2",
    PolicyConfig("blind_pandas", {"prior": RATES4.values}),
])
def test_k4_every_policy_simulates_and_sweeps(policy):
    est = sim.make_estimates(CFG4, "network", 0.1, -1)
    assert est.shape == (24, 4)
    out = sim.simulate(policy, CFG4, 0.7 * CAP4, est, seed=0)
    assert np.isfinite(out["mean_delay"])
    assert out["throughput"] == pytest.approx(0.7 * CAP4, rel=0.15)
    swept = sim.sweep(policy, CFG4, np.array([0.5, 0.7], np.float32) * CAP4,
                      est[None], np.arange(2))
    assert swept["mean_delay"].shape == (2, 1, 2)
    assert np.isfinite(swept["mean_delay"]).all()


@pytest.mark.parametrize("spec,rates", [
    ((), (0.5, 0.25)),
    ((4, 12), (0.5, 0.45, 0.35, 0.25)),
    (((6, 6, 4, 4, 4),), (0.5, 0.45, 0.25)),
])
def test_kernels_match_oracle_on_k_tier_ancestors(spec, rates):
    from repro.kernels import ops, ref
    topo = loc.Topology(24, spec)
    anc = jnp.asarray(topo.ancestors, jnp.int32)
    k = topo.num_tiers
    rng = np.random.default_rng(k)
    m, b = 24, 9
    wlv = jnp.asarray(rng.uniform(0, 50, m), jnp.float32)
    er = jnp.asarray(np.tile(np.asarray(rates, np.float32), (m, 1))
                     * rng.uniform(0.8, 1.2, (m, k)), jnp.float32)
    tl = jnp.sort(jnp.asarray(
        np.stack([rng.choice(m, 3, replace=False) for _ in range(b)]),
        jnp.int32), axis=1)
    s1, t1, sc1 = ops.wwl_route(wlv, er, anc, tl)
    s2, t2, sc2 = ref.wwl_route(wlv, er, anc, tl)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    np.testing.assert_allclose(np.asarray(sc1), np.asarray(sc2), rtol=1e-6)

    q = jnp.asarray(rng.integers(0, 5, m), jnp.float32)
    ids = jnp.asarray(rng.choice(m, b, replace=False), jnp.int32)
    er2 = jnp.asarray(np.tile(np.asarray(rates, np.float32), (b, 1)),
                      jnp.float32)
    q1, s1 = ops.maxweight_claim(q, anc, ids, anc[:, ids], er2)
    q2, s2 = ref.maxweight_claim(q, anc, ids, anc[:, ids], er2)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


def test_k4_kernel_tier_derivation_spot_check():
    """The kernel's tier derivation weighs W/rate with the pod level in
    between rack and remote: a lightly-loaded rack-mate (W/0.45) must beat
    a pod-mate (W/0.35) and a remote server (W/0.25) at workloads chosen so
    only the tier rates discriminate."""
    from repro.kernels import ops
    anc = jnp.asarray(TOPO4.ancestors, jnp.int32)
    # task locals fill rack 0 (servers 0,2,3); server 1 is the rack-mate,
    # 5 sits in the same pod, 13 in the other pod
    wlv = jnp.full((24,), 10.0).at[1].set(0.045).at[5].set(0.07) \
                               .at[13].set(0.05)
    er = jnp.tile(RATES4.as_array()[None], (24, 1))
    tl = jnp.asarray([[0, 2, 3]], jnp.int32)
    server, tier, score = ops.wwl_route(wlv, er, anc, tl)
    # scores: 1 -> .045/.45 = .10; 5 -> .07/.35 = .20; 13 -> .05/.25 = .20
    assert int(server[0]) == 1 and int(tier[0]) == 1
    assert float(score[0]) == pytest.approx(0.1)


# ---------------------------------------------- per-rack arrival weights ---

def test_rack_weights_concentrate_arrivals():
    """p_hot=1 + one-hot rack_weights => every replica set lands in that
    rack (the weighted generalization of hot_rack)."""
    topo = loc.Topology(12, 4)
    rack_of = jnp.asarray(topo.rack_of, jnp.int32)
    w = jnp.asarray([0.0, 0.0, 1.0], jnp.float32)
    types = loc.sample_task_types_at(jax.random.PRNGKey(0), rack_of,
                                     p_hot=1.0, hot_rack=0, batch=128,
                                     rack_weights=w)
    t = np.asarray(types)
    assert (t >= 8).all() and (t < 12).all()   # all in rack 2
    # mixed weights spread hot traffic across the weighted racks
    w = jnp.asarray([0.5, 0.0, 0.5], jnp.float32)
    t = np.asarray(loc.sample_task_types_at(jax.random.PRNGKey(1), rack_of,
                                            1.0, 0, 256, rack_weights=w))
    racks = np.asarray(topo.rack_of)[t[:, 0]]
    assert set(racks.tolist()) == {0, 2}


def test_rack_weight_scenario_shifts_load_between_racks():
    scn = wl.Scenario("skew", (
        wl.Segment(start=0.0, rack_weights=(1.0, 0.0, 0.0)),
        wl.Segment(start=0.5, rack_weights=(0.0, 0.0, 1.0)),
    ))
    cfg = sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                        p_hot=0.5, max_arrivals=16, horizon=1000, warmup=200)
    cap = loc.capacity_hot_rack(cfg.topo, cfg.true_rates, cfg.p_hot)
    est = sim.make_estimates(cfg, "network", 0.0, -1)
    out = sim.simulate("balanced_pandas", cfg, 0.6 * cap, est, seed=0,
                       scenario=scn)
    assert np.isfinite(out["mean_delay"])
    assert out["throughput"] == pytest.approx(0.6 * cap, rel=0.2)
    # compiled schedule carries the (S, R) weight track; static has none
    sched = wl.compile_schedule(scn, cfg.topo, horizon=100, base_p_hot=0.5)
    assert sched.rack_weights is not None and sched.rack_weights.shape == (2, 3)
    assert wl.slot_knobs(sched, jnp.int32(75)).rack_weights[2] == 1.0
    static = wl.compile_schedule(wl.make_scenario("static"), cfg.topo, 100,
                                 0.5)
    assert static.rack_weights is None


def test_rack_weights_validation_and_resize():
    with pytest.raises(ValueError):
        wl.Segment(start=0.0, rack_weights=(0.0, 0.0))      # zero sum
    with pytest.raises(ValueError):
        wl.Segment(start=0.0, rack_weights=(-1.0, 2.0))     # negative
    # shorter vectors cycle over the compiled rack count (like hot_rack
    # wrapping mod num_racks)
    scn = wl.Scenario("s", (wl.Segment(start=0.0, rack_weights=(1.0, 0.0)),))
    sched = wl.compile_schedule(scn, loc.Topology(24, 4), 100, 0.5)
    np.testing.assert_allclose(np.asarray(sched.rack_weights[0]),
                               [1, 0, 1, 0, 1, 0])


def test_rack_weight_scenario_plays_back_on_host_consumers():
    """Regression: weights putting zero mass on rack 0 must not break the
    host projection — locality knobs are simulator-only and host_playback
    discards them instead of resizing them to its rack-less view."""
    scn = wl.Scenario("offrack0", (
        wl.Segment(start=0.0, rack_weights=(0.0, 0.0, 1.0)),))
    pb = wl.host_playback(scn, num_workers=4, horizon=100.0)
    assert pb.lam_mult_at(0.0) == 1.0
    from repro.data.pipeline import DataPipeline, PipelineConfig
    pipe = DataPipeline(PipelineConfig(num_hosts=8, hosts_per_pod=4,
                                       num_chunks=8, tokens_per_chunk=2048,
                                       seq_len=64, global_batch=1,
                                       scenario=scn))
    assert next(pipe)["tokens"].shape == (1, 64)


def test_k2_pipeline_counts_nonlocal_as_remote():
    """Regression: on a 2-tier fleet the only non-local tier IS remote —
    the legacy 3-way counters must not file it under 'rack'."""
    from repro.data.pipeline import DataPipeline, PipelineConfig
    pipe = DataPipeline(PipelineConfig(topology=loc.Topology(8),
                                       tier_rates=(1.0, 0.4),
                                       num_chunks=64,
                                       tokens_per_chunk=1024,
                                       seq_len=64, global_batch=2))
    for _ in range(4):
        next(pipe)
    assert pipe.metrics["rack"] == 0
    assert pipe.metrics["remote"] == int(pipe.metrics["tier_reads"][1])


def test_trace_rack_weights_roundtrip_and_compile(tmp_path):
    arr = np.array([10.0, 12.0, 8.0, 10.0])
    rw = np.array([[1.0, 0.0], [1.0, 0.0], [0.25, 0.75], [0.25, 0.75]])
    tr = wl.Trace("skewed", 60.0, arr, rack_weights=rw)
    p = tmp_path / "skewed.jsonl"
    wl.save_trace(tr, p)
    back = wl.load_trace(p)
    assert back == tr
    with pytest.raises(ValueError):
        wl.save_trace(tr, tmp_path / "skewed.csv")  # no CSV representation
    scn = wl.trace_to_scenario(tr, max_segments=8)
    # the weight change at interval 2 is an aux change-point: never merged
    assert len(scn.segments) >= 2
    assert scn.segments[0].rack_weights == (1.0, 0.0)
    assert scn.segments[-1].rack_weights == (0.25, 0.75)


# --------------------------------------------------- K=4 host-side stack ---

def test_k4_pipeline_end_to_end():
    from repro.data.pipeline import DataPipeline, PipelineConfig
    topo = loc.Topology(8, (2, 4))
    cfg = PipelineConfig(topology=topo, tier_rates=(1.0, 0.8, 0.6, 0.4),
                         num_chunks=32, tokens_per_chunk=4096, seq_len=128,
                         global_batch=2,
                         scenario=wl.Scenario("skew", (
                             wl.Segment(start=0.0, slow_servers={3: 0.5}),)))
    pipe = DataPipeline(cfg)
    batch = next(pipe)
    assert batch["tokens"].shape == (2, 128)
    assert pipe.metrics["tier_reads"].shape == (4,)
    assert pipe.metrics["tier_reads"].sum() == pipe.metrics["reads"]
    with pytest.raises(ValueError):
        DataPipeline(PipelineConfig(topology=topo))  # 3 rates on 4 tiers


def test_k4_engine_end_to_end():
    from repro.configs import registry
    from repro.models import params as P
    from repro.serve.engine import EngineConfig, Request, ServingEngine

    cfg = registry.get_smoke_config("chatglm3_6b")
    prm = P.init_params(cfg, jax.random.PRNGKey(0))
    topo = loc.Topology(4, (2, 4))  # racks of 2 in one pod of 4 + ... K=4
    ecfg = EngineConfig(topology=topo,
                        tier_rates=(1.0, 0.7, 0.55, 0.4),
                        slots_per_replica=2, max_len=64,
                        prefill_buckets=(16,))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=2, prefix_id=i % 3) for i in range(6)]
    eng = ServingEngine(cfg, prm, ecfg)
    assert eng.spec.num_tiers == 4
    assert set(eng.assign_tiers) == {0, 1, 2, 3}
    out = eng.run_until_drained(reqs, max_steps=200)
    assert all(r.finish_time > 0 for r in out)
    assert sum(eng.assign_tiers.values()) == len(reqs)
    with pytest.raises(ValueError):
        ServingEngine(cfg, prm, EngineConfig(topology=topo))  # 3-rate prior
