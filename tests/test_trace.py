"""Trace-driven replay: schema validation, JSONL/CSV round-trips,
unit-mean normalization, change-point merging bounds, bitwise equality of
a constant trace with the static scenario, the registered "trace"
builder, the export hook, and the bundled reference traces."""

import numpy as np
import pytest

from repro import workloads as wl
from repro.core import locality as loc, robustness as rb, simulator as sim
from repro.workloads.trace import (Incident, Trace, bundled_traces,
                                   load_bundled, load_trace, save_trace,
                                   synthesize_trace, trace_from_arrivals,
                                   trace_to_scenario)

CFG = sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                    p_hot=0.5, max_arrivals=16, horizon=2000, warmup=500)
CAP = loc.capacity_hot_rack(CFG.topo, CFG.true_rates, CFG.p_hot)
EXACT = sim.make_estimates(CFG, "network", 0.0, -1)


# ------------------------------------------------------------- schema -----

def test_trace_validation():
    with pytest.raises(ValueError):
        Trace("bad", 60.0, np.empty(0))  # empty
    with pytest.raises(ValueError):
        Trace("bad", 60.0, np.array([1.0, -2.0]))  # negative arrivals
    with pytest.raises(ValueError):
        Trace("bad", 0.0, np.ones(4))  # non-positive interval
    with pytest.raises(ValueError):
        Trace("bad", 60.0, np.ones(4), p_hot=np.array([0.5, 0.5]))  # shape
    with pytest.raises(ValueError):
        Trace("bad", 60.0, np.ones(4), p_hot=np.full(4, 1.5))  # range
    with pytest.raises(ValueError):  # incident past the end
        Trace("bad", 60.0, np.ones(4),
              incidents=(Incident("straggler", 2, 9, servers=(0,)),))


def test_incident_validation():
    with pytest.raises(ValueError):
        Incident("quake", 0, 4)  # unknown kind
    with pytest.raises(ValueError):
        Incident("straggler", 4, 4, servers=(0,))  # empty window
    with pytest.raises(ValueError):
        Incident("straggler", 0, 4)  # no servers
    with pytest.raises(ValueError):
        Incident("straggler", 0, 4, servers=(0,), factor=1.5)
    with pytest.raises(ValueError):
        Incident("rack_congestion", 0, 4, tier_mult=(1.0, 0.0, 1.0))


# -------------------------------------------------------- round-trips ----

@pytest.mark.parametrize("kind,suffix", [("diurnal_week", ".jsonl"),
                                         ("flash_day", ".csv")])
def test_save_load_roundtrip_is_lossless(tmp_path, kind, suffix):
    t = synthesize_trace(kind)
    path = tmp_path / f"t{suffix}"
    save_trace(t, path)
    r = load_trace(path)
    assert r == t
    # export -> load -> compile determinism: recompiling either object
    # yields the identical Scenario
    assert trace_to_scenario(r) == trace_to_scenario(t)
    # and a second save/load cycle is a fixed point
    save_trace(r, tmp_path / f"t2{suffix}")
    assert load_trace(tmp_path / f"t2{suffix}") == r


def test_csv_refuses_incidents(tmp_path):
    t = synthesize_trace("diurnal_week")
    with pytest.raises(ValueError):
        save_trace(t, tmp_path / "t.csv")


def test_jsonl_partial_annotation_rejected(tmp_path):
    p = tmp_path / "t.jsonl"
    p.write_text(
        '{"record": "header", "version": 1, "name": "x", "interval": 60}\n'
        '{"record": "interval", "arrivals": 3, "p_hot": 0.5}\n'
        '{"record": "interval", "arrivals": 4}\n')
    with pytest.raises(ValueError):
        load_trace(p)


def test_bundled_traces_pinned_to_generator():
    """The checked-in example traces are the exact output of
    `synthesize_trace` (seed 0) — regenerate them if this ever fails."""
    assert bundled_traces() == ("diurnal_week", "flash_day")
    for name in bundled_traces():
        assert load_bundled(name) == synthesize_trace(name)
    with pytest.raises(ValueError):
        load_bundled("no_such_trace")


# ----------------------------------------------------------- compiler ----

def test_unit_mean_normalization():
    rng = np.random.default_rng(1)
    t = Trace("noisy", 60.0, rng.poisson(50.0, 700).astype(float))
    scn = trace_to_scenario(t, max_segments=48)
    assert scn.mean_lam_mult == pytest.approx(1.0, abs=1e-9)
    raw = trace_to_scenario(t, max_segments=48, normalize=False)
    assert raw.mean_lam_mult == pytest.approx(float(t.arrivals.mean()),
                                              rel=1e-9)


def test_change_point_merging_bound_on_long_trace():
    rng = np.random.default_rng(2)
    arr = rng.poisson(100 + 40 * np.sin(np.linspace(0, 20, 10_000)),
                      10_000).astype(float)
    scn = trace_to_scenario(Trace("big", 1.0, arr), max_segments=64)
    assert 1 < len(scn.segments) <= 64
    # merging preserves the time-average exactly (equal-length intervals)
    assert scn.mean_lam_mult == pytest.approx(1.0, abs=1e-9)
    # and the shape survives: compiled multipliers still span the sinusoid
    lams = [s.lam_mult for s in scn.segments]
    assert max(lams) - min(lams) > 0.4


def test_aux_change_points_never_merge_away():
    n = 100
    t = Trace("inc", 60.0, np.full(n, 10.0),
              incidents=(Incident("straggler", 40, 60, servers=(1,),
                                  factor=0.5),
                         Incident("rack_congestion", 50, 70,
                                  tier_mult=(1.0, 0.7, 0.6))))
    scn = trace_to_scenario(t, max_segments=8)
    sched = wl.compile_schedule(scn, CFG.topo, horizon=n, base_p_hot=0.5)
    import jax.numpy as jnp
    r45 = np.asarray(wl.slot_knobs(sched, jnp.int32(45)).rate_mult)
    r55 = np.asarray(wl.slot_knobs(sched, jnp.int32(55)).rate_mult)
    r65 = np.asarray(wl.slot_knobs(sched, jnp.int32(65)).rate_mult)
    r80 = np.asarray(wl.slot_knobs(sched, jnp.int32(80)).rate_mult)
    assert r45[1, 0] == pytest.approx(0.5)      # straggler only
    assert r45[0, 1] == pytest.approx(1.0)
    assert r55[1, 1] == pytest.approx(0.5 * 0.7)  # overlap: both compose
    assert r55[0, 2] == pytest.approx(0.6)
    assert r65[1, 0] == pytest.approx(1.0)       # congestion only
    assert r65[0, 1] == pytest.approx(0.7)
    np.testing.assert_allclose(r80, 1.0)


def test_unmergeable_annotations_raise():
    n = 40
    t = Trace("wild", 60.0, np.full(n, 5.0),
              p_hot=np.linspace(0.1, 0.9, n))  # distinct every interval
    with pytest.raises(ValueError, match="quantize"):
        trace_to_scenario(t, max_segments=8)


def test_constant_trace_matches_static_bitwise():
    """Acceptance gate: a constant trace compiles to the static schedule
    and reproduces its simulator sample paths bitwise."""
    const = trace_to_scenario(Trace("const", 60.0, np.full(288, 12.0)))
    assert len(const.segments) == 1
    a = sim.simulate("balanced_pandas", CFG, 0.8 * CAP, EXACT, seed=3,
                     scenario="static")
    b = sim.simulate("balanced_pandas", CFG, 0.8 * CAP, EXACT, seed=3,
                     scenario=const)
    assert a == b


# ----------------------------------------------------- registry + sim ----

def test_trace_scenario_registered_and_options(tmp_path):
    assert "trace" in wl.available_scenarios()
    scn = wl.make_scenario("trace")  # default bundled diurnal week
    assert scn.name == "trace:diurnal_week"
    path = tmp_path / "mine.csv"
    save_trace(Trace("mine", 30.0, np.arange(1.0, 25.0)), path)
    by_path = wl.make_scenario("trace", path=path, max_segments=6)
    assert by_path.name == "trace:mine"
    assert 1 < len(by_path.segments) <= 6
    with pytest.raises(ValueError):
        wl.make_scenario("trace", path=path, name="flash_day")
    with pytest.raises(FileNotFoundError):
        wl.make_scenario("trace", path=tmp_path / "missing.jsonl")


def test_simulate_and_drift_study_replay_trace():
    out = sim.simulate("balanced_pandas", CFG, 0.6 * CAP, EXACT, seed=0,
                       scenario=wl.ScenarioConfig("trace",
                                                  {"name": "flash_day",
                                                   "max_segments": 16}))
    assert np.isfinite(out["mean_delay"])
    assert out["throughput"] == pytest.approx(0.6 * CAP, rel=0.2)
    cfg = rb.StudyConfig(
        sim=sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                          max_arrivals=16, horizon=600, warmup=200),
        seeds=(0,))
    scn = trace_to_scenario(load_bundled("flash_day"), max_segments=16)
    study = rb.drift_study(cfg, scenarios={"replay": scn}, load=0.6)
    assert set(study["delay"]) == {"replay"}
    for arm in ("fixed_prior", "blind_ewma"):
        assert np.isfinite(study["delay"]["replay"][arm]).all()


def test_pipeline_replays_trace_scenario():
    """The data pipeline accepts a trace replay like any other scenario:
    same deterministic tokens, finite virtual clock."""
    from repro.data.pipeline import DataPipeline, PipelineConfig
    kw = dict(num_hosts=8, hosts_per_pod=4, num_chunks=32,
              tokens_per_chunk=4096, seq_len=128, global_batch=2)
    static = DataPipeline(PipelineConfig(**kw))
    replay = DataPipeline(PipelineConfig(
        scenario=wl.ScenarioConfig("trace", {"name": "flash_day",
                                             "max_segments": 16}),
        scenario_horizon=64.0, **kw))
    b0, b1 = next(static), next(replay)
    np.testing.assert_array_equal(b0["tokens"], b1["tokens"])
    assert np.isfinite(replay.metrics["virtual_time"])


def test_host_playback_replays_trace_incidents():
    t = Trace("inc", 60.0, np.full(50, 4.0),
              incidents=(Incident("straggler", 20, 30, servers=(2,),
                                  factor=0.25),))
    pb = wl.host_playback(trace_to_scenario(t), num_workers=4, horizon=100.0)
    assert pb.slowdown(50.0, 2) == pytest.approx(4.0)   # inside window
    assert pb.slowdown(10.0, 2) == pytest.approx(1.0)
    steps = wl.arrival_steps(pb, 20, base_per_step=0.5)
    assert len(steps) == 20 and (np.diff(steps) >= 0).all()


# ---------------------------------------------------------- export hook ---

def test_trace_from_arrivals_bins_exactly():
    steps = np.array([0, 0, 3, 7, 7, 7, 12, 19])
    t = trace_from_arrivals(steps, num_intervals=4, horizon=20.0,
                            name="rec")
    np.testing.assert_array_equal(t.arrivals, [3.0, 3.0, 1.0, 1.0])
    assert t.interval == pytest.approx(5.0)
    assert t.name == "rec"
    empty = trace_from_arrivals([], num_intervals=3)
    np.testing.assert_array_equal(empty.arrivals, [0.0, 0.0, 0.0])
    with pytest.raises(ValueError):
        trace_from_arrivals([5.0], num_intervals=2, horizon=4.0)
    with pytest.raises(ValueError):
        trace_from_arrivals([1.0], num_intervals=0)


def test_export_replay_loop(tmp_path):
    """record -> save -> load -> compile -> (deterministically) again."""
    rng = np.random.default_rng(0)
    steps = np.sort(rng.integers(0, 400, 200))
    rec = trace_from_arrivals(steps, num_intervals=40, horizon=400.0)
    p = tmp_path / "rec.jsonl"
    save_trace(rec, p)
    back = load_trace(p)
    assert back == rec
    s1 = trace_to_scenario(back, max_segments=16)
    s2 = trace_to_scenario(load_trace(p), max_segments=16)
    assert s1 == s2
    out = sim.simulate("balanced_pandas", CFG, 0.6 * CAP, EXACT, seed=1,
                       scenario=s1)
    again = sim.simulate("balanced_pandas", CFG, 0.6 * CAP, EXACT, seed=1,
                         scenario=s2)
    assert out == again
