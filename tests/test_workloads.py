"""Scenario subsystem: registry, schedule compilation, per-slot gather,
CRN preservation of the static scenario, vmap shape invariance, host
playback, the blind policy, and the drift-study seam."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import workloads as wl
from repro.core import locality as loc, robustness as rb, simulator as sim
from repro.core.policy import PolicyConfig, available_policies, make_policy

CFG = sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                    p_hot=0.5, max_arrivals=16, horizon=2000, warmup=500)
CAP = loc.capacity_hot_rack(CFG.topo, CFG.true_rates, CFG.p_hot)
EXACT = sim.make_estimates(CFG, "network", 0.0, -1)


# ------------------------------------------------------------- registry ---

def test_builtin_scenarios_registered():
    names = wl.available_scenarios()
    for expected in ("static", "diurnal", "flash_crowd", "mmpp", "hot_shift",
                     "stragglers", "rack_congestion"):
        assert expected in names
    assert len(names) >= 4


def test_make_scenario_resolution():
    s = wl.make_scenario("stragglers", factor=0.5)
    assert isinstance(s, wl.Scenario)
    assert wl.make_scenario(s) is s
    cfgd = wl.make_scenario(wl.ScenarioConfig("flash_crowd", {"peak": 2.0}))
    assert cfgd.name == "flash_crowd"
    assert wl.make_scenario(None).name == "static"
    with pytest.raises(ValueError):
        wl.make_scenario("no_such_scenario")
    with pytest.raises(ValueError):
        wl.make_scenario(s, factor=0.5)  # options need a name


def test_declarative_validation():
    with pytest.raises(ValueError):
        wl.Segment(start=1.5)
    with pytest.raises(ValueError):
        wl.Segment(start=0.0, lam_mult=-1.0)
    with pytest.raises(ValueError):
        wl.Segment(start=0.0, tier_mult=(1.0, 0.0, 1.0))
    with pytest.raises(ValueError):
        wl.Scenario("bad", ())  # empty
    with pytest.raises(ValueError):
        wl.Scenario("bad", (wl.Segment(start=0.5),))  # must start at 0
    with pytest.raises(ValueError):
        wl.Scenario("bad", (wl.Segment(start=0.0), wl.Segment(start=0.0)))


# ------------------------------------------------- schedule compilation ---

def test_segment_gather_correctness():
    scn = wl.make_scenario("flash_crowd", peak=2.0, start=0.4, width=0.2)
    sched = wl.compile_schedule(scn, CFG.topo, horizon=1000, base_p_hot=0.5)
    base = 1.0 / (1.0 - 0.2 + 2.0 * 0.2)
    for t, want in ((0, base), (399, base), (400, 2.0 * base),
                    (599, 2.0 * base), (600, base), (999, base)):
        knobs = wl.slot_knobs(sched, jnp.int32(t))
        assert float(knobs.lam_mult) == pytest.approx(want), t
        assert knobs.rate_mult.shape == (12, 3)
        np.testing.assert_allclose(np.asarray(knobs.rate_mult), 1.0)
    assert scn.mean_lam_mult == pytest.approx(1.0)


def test_stragglers_rate_mult_window():
    scn = wl.make_scenario("stragglers", servers=(0, 5), factor=0.25,
                           start=0.25, width=0.5)
    sched = wl.compile_schedule(scn, CFG.topo, horizon=400, base_p_hot=0.5)
    inside = np.asarray(wl.slot_knobs(sched, jnp.int32(200)).rate_mult)
    outside = np.asarray(wl.slot_knobs(sched, jnp.int32(50)).rate_mult)
    np.testing.assert_allclose(outside, 1.0)
    np.testing.assert_allclose(inside[0], 0.25)
    np.testing.assert_allclose(inside[5], 0.25)
    np.testing.assert_allclose(inside[1], 1.0)


def test_rack_congestion_sags_beta_gamma_only():
    scn = wl.make_scenario("rack_congestion", beta_mult=0.6, gamma_mult=0.5,
                           start=0.4, width=0.4)
    sched = wl.compile_schedule(scn, CFG.topo, horizon=100, base_p_hot=0.5)
    mid = np.asarray(wl.slot_knobs(sched, jnp.int32(50)).rate_mult)
    np.testing.assert_allclose(mid[:, 0], 1.0)
    np.testing.assert_allclose(mid[:, 1], 0.6)
    np.testing.assert_allclose(mid[:, 2], 0.5)


def test_hot_shift_wraps_rack_ids():
    scn = wl.make_scenario("hot_shift", phases=6)  # topo has only 3 racks
    sched = wl.compile_schedule(scn, CFG.topo, horizon=600, base_p_hot=0.5)
    racks = [int(wl.slot_knobs(sched, jnp.int32(t)).hot_rack)
             for t in (0, 100, 200, 300, 400, 500)]
    assert racks == [0, 1, 2, 0, 1, 2]
    assert max(racks) < CFG.topo.num_racks


def test_mmpp_deterministic_and_unit_mean():
    a = wl.make_scenario("mmpp", seed=3)
    b = wl.make_scenario("mmpp", seed=3)
    assert a == b
    assert len(a.segments) >= 4
    assert a.mean_lam_mult == pytest.approx(1.0, abs=1e-6)
    assert wl.make_scenario("mmpp", seed=4) != a


# ----------------------------------------------- simulator integration ----

def test_static_scenario_preserves_crn():
    """The static scenario must reproduce the scenario-free sample path
    bitwise — the Fig. 1 numbers do not move."""
    plain = sim.simulate("balanced_pandas", CFG, 0.8 * CAP, EXACT, seed=3)
    static = sim.simulate("balanced_pandas", CFG, 0.8 * CAP, EXACT, seed=3,
                          scenario="static")
    assert plain == static


def test_arrivals_share_stream_across_scenario_fault_injection():
    """Fault-only scenarios leave the arrival stream untouched (common
    random numbers): throughput-in == arrivals for both, same seed."""
    a = sim.simulate("priority", CFG, 0.7 * CAP, EXACT, seed=5)
    b = sim.simulate("priority", CFG, 0.7 * CAP, EXACT, seed=5,
                     scenario=wl.make_scenario("stragglers", factor=0.9))
    # same arrival stream, mildly slower service: delay may move but the
    # run is still paired — identical seeds, nearly identical dynamics
    assert b["mean_n"] >= a["mean_n"] * 0.9


@pytest.mark.parametrize("scenario", ["diurnal", "flash_crowd", "mmpp",
                                      "hot_shift", "stragglers",
                                      "rack_congestion"])
def test_every_builtin_scenario_runs_by_name(scenario):
    out = sim.simulate("balanced_pandas", CFG, 0.6 * CAP, EXACT, seed=0,
                       scenario=scenario)
    assert np.isfinite(out["mean_delay"])
    assert out["throughput"] == pytest.approx(0.6 * CAP, rel=0.2)


def test_sweep_shapes_invariant_under_vmap_with_scenario():
    lam = np.array([0.6, 0.8], np.float32) * CAP
    ests = np.stack([EXACT, sim.make_estimates(CFG, "per_server", 0.3, 1)])
    out = sim.sweep("balanced_pandas", CFG, lam, ests, np.arange(3),
                    scenario="diurnal")
    assert out["mean_delay"].shape == (2, 2, 3)
    assert np.isfinite(out["mean_delay"]).all()


# --------------------------------------------------------- blind policy ---

def test_blind_pandas_registered_and_options():
    assert "blind_pandas" in available_policies()
    with pytest.raises(ValueError):
        make_policy(PolicyConfig("blind_pandas", {"prior": (2.0, 1.0, 0.5)}))
    with pytest.raises(ValueError):
        make_policy(PolicyConfig("blind_pandas", {"decay": 1.5}))


def test_blind_pandas_conserves_tasks_and_learns():
    # Deliberately wrong prior: alpha believed 0.9 while the truth is 0.5 —
    # the EWMA must pull the busy local estimates toward the truth.
    policy = make_policy(PolicyConfig("blind_pandas",
                                      {"prior": (0.9, 0.45, 0.25)}))
    topo = CFG.topo
    rack_of = jnp.asarray(topo.rack_of, jnp.int32)
    true3 = CFG.true_rates.as_array()
    est = jnp.asarray(EXACT)
    s = policy.init_state(topo)
    step = jax.jit(lambda s, k, ty, ac: policy.slot_step(
        s, k, ty, ac, est, true3, rack_of))
    traffic = loc.Traffic(lam_total=4.0, p_hot=0.5, max_arrivals=16)
    arrived = completed = 0
    for t in range(300):
        key = jax.random.PRNGKey(t)
        types, active = loc.sample_arrivals(jax.random.fold_in(key, 1),
                                            topo, traffic)
        s, compl = step(s, jax.random.fold_in(key, 2), types, active)
        arrived += int(jnp.sum(active))
        completed += int(compl)
    assert int(policy.num_in_system(s)) == arrived - completed
    ests = np.asarray(policy.estimates(s))
    assert (ests > 0).all() and (ests <= 1.0).all()
    # Local queues get the most observations: the learned alpha column must
    # have moved off the 0.9 prior toward the 0.5 truth on average.
    assert ests[:, 0].mean() < 0.75, ests[:, 0]


def test_blind_pandas_stable_at_moderate_load():
    out = sim.simulate("blind_pandas", CFG, 0.7 * CAP, EXACT, seed=0)
    assert out["throughput"] == pytest.approx(0.7 * CAP, rel=0.1)
    assert out["final_n"] < 200


# ------------------------------------------------------- host playback ----

def test_host_playback_wraps_and_matches_segments():
    scn = wl.make_scenario("flash_crowd", peak=2.0, start=0.4, width=0.2)
    pb = wl.host_playback(scn, num_workers=4, horizon=100.0)
    base = 1.0 / (1.0 - 0.2 + 2.0 * 0.2)
    assert pb.lam_mult_at(0.0) == pytest.approx(base)
    assert pb.lam_mult_at(50.0) == pytest.approx(2.0 * base)
    assert pb.lam_mult_at(150.0) == pytest.approx(2.0 * base)  # wraps
    assert pb.rate_mult_at(10.0, 0) == 1.0


def test_host_playback_straggler_slowdown():
    scn = wl.make_scenario("stragglers", servers=(1,), factor=0.25,
                           start=0.25, width=0.5)
    pb = wl.host_playback(scn, num_workers=4, horizon=100.0)
    assert pb.slowdown(50.0, 1) == pytest.approx(4.0)
    assert pb.slowdown(50.0, 0) == pytest.approx(1.0)
    assert pb.slowdown(10.0, 1) == pytest.approx(1.0)


def test_mean_lam_mult_over_window_edge_cases():
    """Regressions for the measurement-window helper: zero-length and
    inverted windows raise (they used to return NaN), negative start
    raises (it used to wrap onto the final segment), and windows that
    start or end mid-segment weigh the truncated segment exactly."""
    scn = wl.make_scenario("flash_crowd", peak=2.0, start=0.4, width=0.2)
    sched = wl.compile_schedule(scn, CFG.topo, horizon=1000, base_p_hot=0.5)
    base = 1.0 / (1.0 - 0.2 + 2.0 * 0.2)
    with pytest.raises(ValueError):
        wl.mean_lam_mult_over(sched, 1000, 1000)  # zero-length
    with pytest.raises(ValueError):
        wl.mean_lam_mult_over(sched, 800, 400)    # inverted
    with pytest.raises(ValueError):
        wl.mean_lam_mult_over(sched, -5, 1000)    # negative start
    # window truncating the final segment: one slot, pure base rate
    assert wl.mean_lam_mult_over(sched, 999, 1000) == pytest.approx(base)
    # window starting mid-surge: 100 surge slots + 300 base slots
    want = (100 * 2.0 * base + 300 * base) / 400
    assert wl.mean_lam_mult_over(sched, 500, 900) == pytest.approx(want)
    # whole-run average matches the declarative mean exactly
    assert wl.mean_lam_mult_over(sched, 0, 1000) == pytest.approx(1.0)
    # agreement with the O(window) per-slot gather it replaced
    per_slot = np.asarray([float(wl.slot_knobs(sched, jnp.int32(t)).lam_mult)
                           for t in range(250, 700)]).mean()
    assert wl.mean_lam_mult_over(sched, 250, 700) == pytest.approx(per_slot)


def test_arrival_steps_zero_requests():
    """Regression: n_requests == 0 returns an empty plan (and negative
    counts raise) instead of tripping numpy internals."""
    pb = wl.host_playback(wl.make_scenario("static"), num_workers=2,
                          horizon=10.0)
    steps = wl.arrival_steps(pb, 0, base_per_step=0.5)
    assert steps.shape == (0,) and steps.dtype == np.int64
    with pytest.raises(ValueError):
        wl.arrival_steps(pb, -1, base_per_step=0.5)
    with pytest.raises(ValueError):
        wl.arrival_steps(pb, 4, base_per_step=0.0)


def test_arrival_steps_follow_intensity():
    scn = wl.make_scenario("flash_crowd", peak=3.0, start=0.5, width=0.3)
    pb = wl.host_playback(scn, num_workers=4, horizon=100.0)
    steps = wl.arrival_steps(pb, 30, base_per_step=0.5)
    assert len(steps) == 30
    assert (np.diff(steps) >= 0).all()
    # more arrivals per step inside the surge window [50, 80)
    in_surge = ((steps >= 50) & (steps < 80)).sum()
    before = (steps < 50).sum()
    assert in_surge / 30.0 > 0.3 or before == 30  # surge densifies arrivals


def test_pipeline_scenario_playback():
    from repro.data.pipeline import DataPipeline, PipelineConfig
    kw = dict(num_hosts=8, hosts_per_pod=4, num_chunks=32,
              tokens_per_chunk=4096, seq_len=128, global_batch=2)
    static = DataPipeline(PipelineConfig(**kw))
    slow = DataPipeline(PipelineConfig(
        scenario="stragglers", scenario_horizon=64.0, **kw))
    b0, b1 = next(static), next(slow)
    # same deterministic tokens regardless of scenario (reads reorder time,
    # not data)
    np.testing.assert_array_equal(b0["tokens"], b1["tokens"])
    assert slow.metrics["reads"] == static.metrics["reads"]
    assert np.isfinite(slow.metrics["virtual_time"])


# ----------------------------------------------------------- drift seam ---

def test_run_study_accepts_scenario():
    cfg = rb.StudyConfig(
        sim=sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                          max_arrivals=16, horizon=500, warmup=100),
        loads=(0.6,), eps_grid=(0.2,), seeds=(0,))
    out = rb.run_study(cfg, algos=("balanced_pandas",), signs=(-1,),
                       scenario="flash_crowd")
    assert out["delay"]["balanced_pandas"].shape == (1, 2, 1)
    assert np.isfinite(out["delay"]["balanced_pandas"]).all()


def test_drift_study_seam_runs_tiny():
    cfg = rb.StudyConfig(
        sim=sim.SimConfig(topo=loc.Topology(12, 4), true_rates=loc.Rates(),
                          max_arrivals=16, horizon=600, warmup=200),
        seeds=(0,))
    study = rb.drift_study(cfg, scenarios=("static", "stragglers"), load=0.6)
    assert set(study["delay"]) == {"static", "stragglers"}
    for scen in study["delay"]:
        for arm in ("fixed_prior", "blind_ewma"):
            assert np.isfinite(study["delay"][scen][arm]).all()
    assert isinstance(study["blind_wins"]["stragglers"], bool)


@pytest.mark.slow
def test_blind_beats_fixed_prior_under_drift():
    """The drift study's headline: with the truth moving (rack-switch
    congestion sagging beta/gamma mid-run), the blind EWMA arm must
    undercut the (initially exact) fixed prior — see EXPERIMENTS.md."""
    cfg = rb.StudyConfig(
        sim=sim.default_config(horizon=8_000, warmup=2_000),
        seeds=(0,))
    study = rb.drift_study(cfg, scenarios=("rack_congestion",), load=0.75)
    d_fix = float(study["delay"]["rack_congestion"]["fixed_prior"].mean())
    d_blind = float(study["delay"]["rack_congestion"]["blind_ewma"].mean())
    assert d_blind < d_fix, (d_blind, d_fix)
