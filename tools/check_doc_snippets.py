"""Execute every fenced ``python`` code block in README.md and docs/*.md.

The docs promise their snippets run; this script keeps the promise
enforceable in CI (the `docs` job) and locally:

    PYTHONPATH=src python tools/check_doc_snippets.py [files...]

Each block executes in its own namespace with the repo root on sys.path
(so `benchmarks`/`examples` imports work like they do for a user in a
checkout).  Blocks fenced as anything other than ``python`` (e.g.
``bash``, ``text``) are ignored.  Exit status is the number of failing
blocks.
"""

from __future__ import annotations

import re
import sys
import traceback
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
FENCE = re.compile(r"^```(\w*)\s*$")


def blocks(path: Path):
    """Yield (first_line_number, source) for each ```python fence."""
    lang, start, buf = None, 0, []
    for ln, line in enumerate(path.read_text().splitlines(), 1):
        m = FENCE.match(line.strip())
        if m and lang is None:
            lang, start, buf = m.group(1) or "text", ln + 1, []
        elif m:
            if lang == "python":
                yield start, "\n".join(buf)
            lang = None
        elif lang is not None:
            buf.append(line)
    if lang is not None:
        # an unterminated fence must fail loudly, not vanish from the run
        raise SystemExit(f"{path}:{start - 1}: ```{lang} fence never closed")


def main(argv) -> int:
    targets = [Path(a) for a in argv] or \
        [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    sys.path.insert(0, str(ROOT))
    failures = 0
    for path in targets:
        for ln, src in blocks(path):
            where = f"{path.relative_to(ROOT)}:{ln}"
            try:
                exec(compile(src, where, "exec"), {"__name__": "snippet"})
            except Exception:
                failures += 1
                print(f"FAIL {where}", file=sys.stderr)
                traceback.print_exc()
            else:
                print(f"ok   {where}")
    print(f"{failures} failing snippet(s)" if failures
          else "all doc snippets ran")
    return failures


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
